"""Multi-head Latent Attention (DeepSeek-V2/V3) with absorbed decode.

Training / prefill expand the compressed KV latent into per-head keys and
values (standard path).  Decode uses the **absorbed** formulation: queries
are folded through ``W_uk`` so attention runs directly against the cached
latent ``c_kv [b, s, r_kv]`` — the KV cache is ``r_kv + r_rope`` floats per
token instead of ``2 * n_heads * head_dim`` (for V3: 576 vs 32768, a 57x
cache shrink; this is the production serving path).

RoPE applies only to the decoupled rope sub-dimensions; the shared key-rope
is broadcast across heads.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.paged_attention import gather_pages, write_token_to_pages
from .layers import Init, apply_rope, norm_init, rms_norm, rope_freqs

__all__ = ["MLAConfig", "mla_init", "mla_apply_full", "mla_decode",
           "mla_init_cache", "mla_init_paged_cache", "mla_decode_paged",
           "mla_param_count", "mla_fwd_flops"]


@dataclass(frozen=True)
class MLAConfig:
    n_heads: int = 128
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 1e4

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_init(init: Init, cfg: MLAConfig, d_model: int, *, dtype=jnp.bfloat16):
    h, rq, rkv = cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank
    s = d_model ** -0.5
    p = {
        "w_dq": init.normal((d_model, rq), s, dtype),
        "q_norm": norm_init(rq, dtype=dtype)[0],
        "w_uq": init.normal((rq, h * cfg.qk_dim), rq ** -0.5, dtype),
        "w_dkv": init.normal((d_model, rkv + cfg.qk_rope_dim), s, dtype),
        "kv_norm": norm_init(rkv, dtype=dtype)[0],
        "w_uk": init.normal((rkv, h * cfg.qk_nope_dim), rkv ** -0.5, dtype),
        "w_uv": init.normal((rkv, h * cfg.v_head_dim), rkv ** -0.5, dtype),
        "w_o": init.normal((h * cfg.v_head_dim, d_model),
                           (h * cfg.v_head_dim) ** -0.5, dtype),
    }
    spec = {
        "w_dq": (None, None),
        "q_norm": {"scale": (None,)},
        "w_uq": (None, "heads"),
        "w_dkv": (None, None),
        "kv_norm": {"scale": (None,)},
        "w_uk": (None, "heads"),
        "w_uv": (None, "heads"),
        "w_o": ("heads", None),
    }
    return p, spec


def _project_q(p, cfg: MLAConfig, x, positions, inv_freq):
    b, s, _ = x.shape
    h = cfg.n_heads
    q = rms_norm(p["q_norm"], x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(b, s, h, cfg.qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, inv_freq)
    return q_nope, q_rope


def _compress_kv(p, cfg: MLAConfig, x, positions, inv_freq):
    ckv_rope = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(ckv_rope, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, inv_freq)[:, :, 0]
    return c_kv, k_rope                      # [b,s,r_kv], [b,s,rope]


def mla_apply_full(p, cfg: MLAConfig, x: jax.Array,
                   positions: jax.Array, *,
                   q_chunk: int = 1024) -> tuple[jax.Array, dict]:
    """Full-expansion MLA (training / prefill).  Returns (out, cache).

    Queries are processed in ``q_chunk`` blocks under remat so the score
    tensor peaks at ``[b, h, q_chunk, s]`` — without this the 32k-prefill
    cell materializes an s x s score map per head (225 GB/device in the
    dry-run; see EXPERIMENTS.md §Dry-run)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    inv_freq = rope_freqs(cfg.qk_rope_dim, cfg.rope_theta)

    q_nope, q_rope = _project_q(p, cfg, x, positions, inv_freq)
    c_kv, k_rope = _compress_kv(p, cfg, x, positions, inv_freq)

    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, cfg.v_head_dim)
    scale = cfg.qk_dim ** -0.5

    # §Perf (dsv3 hillclimb): concatenate the nope and rope sub-dims and
    # broadcast the shared key-rope across heads so scores come from ONE
    # head-sharded einsum.  The two-einsum form made GSPMD all-reduce
    # full f32 score gradients over `model` in the backward (2.1 GB x
    # 3712 executions/step measured in the dry-run).  The explicit
    # constraints pin the head dim to `model` — without them the solver
    # shards the 192-wide contraction dim instead and partial-sums the
    # scores (25 TB/step measured).
    from ..parallel.sharding import maybe_constrain
    kq = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.qk_rope_dim))], axis=-1)
    kq = maybe_constrain(kq, None, None, "model", None)
    v = maybe_constrain(v, None, None, "model", None)

    def attend(qc, qp):
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, kq,
                            preferred_element_type=jnp.float32) * scale
        mask = positions[:, None, None, :] <= qp[:, None, :, None]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_cat = maybe_constrain(q_cat, None, None, "model", None)
    if s <= q_chunk:
        out = attend(q_cat, positions)
    else:
        pad = (-s) % q_chunk
        padq = lambda a: jnp.pad(a, [(0, 0), (0, pad)]
                                 + [(0, 0)] * (a.ndim - 2))
        qq, qp = padq(q_cat), padq(positions)
        nc = qq.shape[1] // q_chunk
        reshp = lambda a: jnp.moveaxis(
            a.reshape((b, nc, q_chunk) + a.shape[2:]), 1, 0)

        def body(_, xs):
            return None, jax.checkpoint(attend)(*xs)

        _, out = jax.lax.scan(body, None, (reshp(qq), reshp(qp)))
        out = jnp.moveaxis(out, 0, 1).reshape(
            (b, nc * q_chunk) + out.shape[3:])[:, :s]
    out = out.reshape(b, s, -1)
    return out @ p["w_o"], {"c_kv": c_kv, "k_rope": k_rope}


def mla_init_cache(cfg: MLAConfig, batch: int, max_seq: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
    }


def _absorbed_attend(p, cfg: MLAConfig, q_nope, q_rope, c_kv, k_rope,
                     pos, dtype) -> jax.Array:
    """Absorbed attention against a latent stream ``c_kv [b, sk, r_kv]``
    / ``k_rope [b, sk, rope]`` with per-lane valid length ``pos + 1``.
    Shared by the contiguous and paged decode paths so the two can never
    drift numerically — queries fold through ``W_uk`` and the combine
    through ``W_uv``, so scores/outputs live in rank space."""
    b = q_nope.shape[0]
    h = cfg.n_heads
    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    q_c = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)          # [b,1,h,r_kv]

    scale = cfg.qk_dim ** -0.5
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_c, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    sk = c_kv.shape[1]
    valid = jnp.arange(sk)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)

    o_c = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)           # [b,1,h,r_kv]
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_c, w_uv).reshape(b, 1, -1)
    return o @ p["w_o"]


def mla_decode(p, cfg: MLAConfig, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed one-token decode.  ``x: [b, 1, d]``, ``pos: [b]`` (0-based
    write position == number of valid cache entries)."""
    inv_freq = rope_freqs(cfg.qk_rope_dim, cfg.rope_theta)
    positions = pos[:, None]

    q_nope, q_rope = _project_q(p, cfg, x, positions, inv_freq)  # [b,1,h,*]
    c_new, kr_new = _compress_kv(p, cfg, x, positions, inv_freq)

    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos[0], axis=1)
    cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos[0], axis=1)

    out = _absorbed_attend(p, cfg, q_nope, q_rope, cache["c_kv"],
                           cache["k_rope"], pos, x.dtype)
    return out, cache


def mla_init_paged_cache(cfg: MLAConfig, n_pages: int, page_size: int,
                         dtype) -> dict:
    """Latent KV page pool (c_kv + decoupled key-rope, per page)."""
    return {
        "c_kv": jnp.zeros((n_pages, page_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_pages, page_size, cfg.qk_rope_dim), dtype),
    }


def mla_decode_paged(p, cfg: MLAConfig, x: jax.Array, pages: dict,
                     block_tables: jax.Array, pos: jax.Array,
                     active: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed one-token decode against a paged latent cache.

    ``x [slots, 1, d]``; ``pages`` hold ``c_kv [n_pages, ps, r_kv]`` /
    ``k_rope [n_pages, ps, rope]``; ``block_tables [slots, max_blocks]``
    int32 page ids; ``pos [slots]`` per-slot write position; ``active
    [slots]`` gates the page write (inactive lanes write the reserved
    trash page 0 so a retired slot's stale block table can never corrupt
    a page that has been re-allocated to a new tenant).

    The MLA pool is paged for *capacity* only: the latent stream is
    gathered back to position order and attended by the same
    :func:`_absorbed_attend` the contiguous decode uses (the absorbed
    score/combine math is rank-space, not head-space, so the GQA paged
    kernel does not apply).
    """
    inv_freq = rope_freqs(cfg.qk_rope_dim, cfg.rope_theta)
    positions = pos[:, None]

    q_nope, q_rope = _project_q(p, cfg, x, positions, inv_freq)
    c_new, kr_new = _compress_kv(p, cfg, x, positions, inv_freq)

    c_pages = write_token_to_pages(pages["c_kv"], block_tables, pos,
                                   active, c_new[:, 0])
    r_pages = write_token_to_pages(pages["k_rope"], block_tables, pos,
                                   active, kr_new[:, 0])
    c_kv = gather_pages(c_pages, block_tables)        # [b, sk, r_kv]
    k_rope = gather_pages(r_pages, block_tables)

    out = _absorbed_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, pos,
                           x.dtype)
    return out, {"c_kv": c_pages, "k_rope": r_pages}


# ---------------------------------------------------------------------------
# Analytic accounting
# ---------------------------------------------------------------------------

def mla_param_count(cfg: MLAConfig, d_model: int) -> int:
    h = cfg.n_heads
    n = d_model * cfg.q_lora_rank + cfg.q_lora_rank                 # dq+norm
    n += cfg.q_lora_rank * h * cfg.qk_dim                           # uq
    n += d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim)             # dkv
    n += cfg.kv_lora_rank                                           # kv norm
    n += cfg.kv_lora_rank * h * (cfg.qk_nope_dim + cfg.v_head_dim)  # uk+uv
    n += h * cfg.v_head_dim * d_model                               # o
    return n


def mla_fwd_flops(cfg: MLAConfig, d_model: int, tokens: int,
                  seq_len: int) -> float:
    """Forward FLOPs of full-expansion MLA over ``tokens`` (train/prefill)."""
    h = cfg.n_heads
    proj = mla_param_count(cfg, d_model) - cfg.q_lora_rank - cfg.kv_lora_rank
    flops = 2.0 * tokens * proj                                    # projections
    flops += 2.0 * tokens * seq_len * h * (cfg.qk_dim + cfg.v_head_dim)
    return flops
