"""Quickstart: profile -> Algorithm 2 schedule -> bubble fill -> train.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_arch
from repro.core import (HardwareSpec, analytic_profile, build_plan,
                        simulate_period)
from repro.core.time_model import Partition
from repro.data import MarkovCorpus
from repro.optim import make_optimizer
from repro.runtime import Runner, StepConfig, init_train_state

W, H, STEPS = 8, 5, 40

# 1. a model (reduced granite config so it actually trains on CPU)
arch = get_arch("granite-3-2b")
model = arch.make_smoke()
print(f"model: {model.cfg.name}, {model.param_count() / 1e6:.2f}M params, "
      f"{len(model.unit_layout())} schedulable units")

# 2. profile the layers for a 1 GB/s geo link
hw = HardwareSpec(bandwidth=1e9, n_workers=W)
profile = analytic_profile(model.layer_costs(batch=4, seq=64), hw)
print(f"comm/compute ratio: {profile.comm_compute_ratio():.2f}")

# 3. search the partition (Algorithm 2) + fill bubbles (§3.4)
plan = build_plan("dreamddp", profile, H)
print(f"partition (BP-order counts): {plan.meta['partition_counts']}")
print(f"supplementary syncs/period:  {plan.meta['extra_syncs']}")
for h in range(H):
    print(f"  phase {h}: sync units {plan.units_for_phase(h)}")

# 4. predicted period timeline vs baselines
part = Partition(tuple(plan.meta["partition_counts"]))
t = sum(x.iteration_time for x in simulate_period(profile, part)) / H
print(f"predicted iteration time: {t * 1e3:.1f} ms "
      f"(vs S-SGD {1e3 * (profile.t_fp_total + profile.t_bp_total + profile.t_comm_total):.1f} ms)")

# 5. train for real
opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
cfg = StepConfig(track_divergence=True)
state = init_train_state(model, opt, jax.random.PRNGKey(0), W, cfg=cfg)
data = MarkovCorpus(vocab=model.cfg.vocab, seq_len=64, batch_per_worker=4,
                    n_workers=W)
runner = Runner(model, opt, plan, data, step_cfg=cfg)
state = runner.run(state, STEPS)
h0, h1 = runner.history[0], runner.history[-1]
print(f"loss {h0['loss']:.3f} -> {h1['loss']:.3f}; "
      f"divergence {h1['divergence']:.2e}")
