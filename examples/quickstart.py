"""Quickstart: the ``repro.api.Session`` facade, end to end.

    PYTHONPATH=src python examples/quickstart.py

One object runs the whole DreamDDP pipeline — profile the layers,
search the partition (Algorithm 2), fill bubbles (§3.4), compile one
executable per phase, and train::

    sess = Session(JobConfig(arch="granite-3-2b", algo="dreamddp",
                             workers=8, period=5, bandwidth=1e9))
    sess.fit(40)

``algo`` names a pluggable :class:`repro.api.SyncStrategy` — the paper's
algorithms (ssgd/wfbp/ascwfbp/flsgd/plsgd-enp/dreamddp) and beyond-paper
compositions (dreamddp-int8, hier-2tier) ship registered; add your own::

    from repro.api import SyncStrategy, register_strategy

    @register_strategy("sync-everything")
    class SyncEverything(SyncStrategy):
        def build_plan(self, profile, H, *, fill_mode="exact"):
            n = len(profile)
            return SyncPlan(algo=self.name, comm="parameters", H=1,
                            n_units=n, phase_units=(tuple(range(n)),))

A strategy owns its plan construction, its communication mode (gradients
vs. parameters) and its sync hook (plain mean / int8+EF / outer
optimizer), so nothing else in the codebase needs to know its name.
"""

from repro.api import JobConfig, Session, available_strategies
from repro.core import simulate_period
from repro.core.time_model import Partition

W, H, STEPS = 8, 5, 40

sess = Session(JobConfig(arch="granite-3-2b", algo="dreamddp", workers=W,
                         period=H, bandwidth=1e9, batch_per_worker=4,
                         seq=64, lr=3e-3, warmup_steps=5, decay_steps=400,
                         track_divergence=True))
print(f"registered strategies: {', '.join(available_strategies())}")

# 1. a model (reduced granite config so it actually trains on CPU)
model = sess.model
print(f"model: {model.cfg.name}, {model.param_count() / 1e6:.2f}M params, "
      f"{len(model.unit_layout())} schedulable units")

# 2. the layer profile for a 1 GB/s geo link
profile = sess.profile()
print(f"comm/compute ratio: {profile.comm_compute_ratio():.2f}")

# 3. the strategy's schedule (Algorithm 2 + §3.4 bubble fill)
plan = sess.plan
print(f"partition (BP-order counts): {plan.meta['partition_counts']}")
print(f"supplementary syncs/period:  {plan.meta['extra_syncs']}")
for h in range(H):
    print(f"  phase {h}: sync units {plan.units_for_phase(h)}")

# 4. predicted period timeline vs baselines
part = Partition(tuple(plan.meta["partition_counts"]))
t = sum(x.iteration_time for x in simulate_period(profile, part)) / H
print(f"predicted iteration time: {t * 1e3:.1f} ms "
      f"(vs S-SGD {1e3 * (profile.t_fp_total + profile.t_bp_total + profile.t_comm_total):.1f} ms)")

# 5. train for real
sess.fit(STEPS)
h0, h1 = sess.history[0], sess.history[-1]
print(f"loss {h0['loss']:.3f} -> {h1['loss']:.3f}; "
      f"divergence {h1['divergence']:.2e}")
