"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A scaled-down granite-family config (~100M params) on the synthetic
Markov corpus, driven through the :class:`repro.api.Session` facade with
an explicit model override (the ``model=`` keyword replaces the arch
registry lookup).  On this CPU container a step takes a few seconds;
pass --steps to shorten.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse

from repro.api import JobConfig, Session
from repro.checkpoint import CheckpointManager
from repro.models.transformer import DecoderLM, LMConfig

CFG_100M = LMConfig(
    name="granite-100m", n_layers=10, d_model=640, n_heads=10,
    n_kv_heads=2, d_ff=2560, vocab=8192, head_dim=64,
    tie_embeddings=True, param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--period", type=int, default=5)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-worker", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_100m")
    args = ap.parse_args()

    model = DecoderLM(CFG_100M)
    print(f"params: {model.param_count() / 1e6:.1f}M")

    sess = Session(
        JobConfig(algo="dreamddp", workers=args.workers,
                  period=args.period, bandwidth=1e9, seq=args.seq,
                  batch_per_worker=args.batch_per_worker,
                  optimizer="adamw", lr=1e-3, warmup_steps=20,
                  decay_steps=args.steps, weight_decay=0.01,
                  ckpt_every=100),
        model=model,
        ckpt=CheckpointManager(args.ckpt_dir, keep=2))
    plan = sess.plan
    print("plan:", plan.meta["partition_counts"],
          "fills:", plan.meta["extra_syncs"])

    sess.fit(args.steps)
    losses = [h["loss"] for h in sess.history]
    med = sorted(h["time"] for h in sess.history)[len(losses) // 2]
    data = sess.runner.data
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(floor ~{data.entropy_floor():.3f}); {med * 1e3:.0f} ms/step; "
          f"last ckpt step {sess.runner.ckpt.latest_step()}")


if __name__ == "__main__":
    main()
