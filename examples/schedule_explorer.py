"""ASCII timeline explorer for DreamDDP schedules.

Renders one synchronization period: per phase, the BP lane and the comm
lane, with the §3.4 bubble fills marked `+`.

    PYTHONPATH=src python examples/schedule_explorer.py --arch qwen3-1.7b \
        --bandwidth 1e9 --H 5
"""

import argparse

from repro.configs import get_arch
from repro.core import HardwareSpec, analytic_profile, build_plan
from repro.core.time_model import Partition, simulate_phase

WIDTH = 78


def render(profile, plan):
    part = Partition(tuple(plan.meta["partition_counts"]))
    n = plan.n_units
    total = None
    for h, (s, e) in enumerate(part.bp_intervals()):
        base = set(range(s, e))
        fills = {n - 1 - u for u in plan.fill_units[h]}
        tl = simulate_phase(profile, sorted(base | fills))
        if total is None:
            total = max(tl.iteration_time, 1e-12)
        scale = WIDTH / total
        bp_end = int(tl.bp_end * scale)
        lane_bp = "#" * bp_end + "." * (WIDTH - bp_end)
        lane_cm = [" "] * WIDTH
        for i, t0 in tl.comm_start.items():
            t1 = tl.comm_done[i]
            a, b = int(t0 * scale), max(int(t1 * scale), int(t0 * scale) + 1)
            ch = "+" if i in fills else "="
            for x in range(a, min(b, WIDTH)):
                lane_cm[x] = ch
        units = sorted(n - 1 - i for i in base)
        print(f"phase {h}: sync units {units} "
              f"(+{len(fills)} fills), iter {tl.iteration_time * 1e3:.1f} ms,"
              f" exposed comm {tl.exposed_comm * 1e3:.1f} ms")
        print("  BP  |" + lane_bp + "|")
        print("  COMM|" + "".join(lane_cm) + "|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--H", type=int, default=5)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args()

    model = get_arch(args.arch).make_model()
    hw = HardwareSpec(bandwidth=args.bandwidth, n_workers=args.workers,
                      latency=1e-3)
    prof = analytic_profile(model.layer_costs(args.batch, args.seq), hw)
    plan = build_plan("dreamddp", prof, args.H)
    print(f"{args.arch}: {plan.n_units} units, H={args.H}, "
          f"bw={args.bandwidth:.0e} B/s, comm/compute "
          f"{prof.comm_compute_ratio():.2f}")
    render(prof, plan)


if __name__ == "__main__":
    main()
