"""Geo-distributed scenario: how the schedule adapts to the WAN link.

Sweeps the inter-datacenter bandwidth from 10 MB/s to 20 GB/s for the
full granite-3-2b config and shows the Algorithm-2 partition, the phase
timelines, and DreamDDP's speedup over S-SGD / ASC-WFBP / FLSGD at each
point (the paper's Figs 1-2 + Table 1 story).

The sweep is one :class:`repro.api.Session` and five ``replan()`` calls —
bandwidth drift is first-class: each call cheaply re-derives the comm
profile and re-solves the partition (the schedule is data, not code).

    PYTHONPATH=src python examples/geo_distributed.py
"""

from repro.api import JobConfig, Session
from repro.core import (ascwfbp_iteration_time, flsgd_period_time,
                        simulate_period, ssgd_iteration_time)
from repro.core.time_model import Partition

H, W = 5, 32
sess = Session(JobConfig(arch="granite-3-2b", algo="dreamddp", smoke=False,
                         workers=W, period=H, batch_per_worker=8, seq=4096,
                         bandwidth=1e7, latency=1e-3,
                         chips_per_worker=256))   # one worker = one pod

print(f"{'bandwidth':>12} {'ratio':>7} {'partition':>22} "
      f"{'dream ms':>9} {'ssgd ms':>9} {'ascwfbp':>9} {'flsgd':>9} "
      f"{'S1':>6} {'S2':>6}")
for bw in (1e7, 1e8, 1e9, 5e9, 2e10):
    plan = sess.replan(bandwidth=bw)
    prof = sess.profile()
    part = Partition(tuple(plan.meta["partition_counts"]))
    n = plan.n_units
    fills = [[n - 1 - u for u in f] for f in plan.fill_units]
    dream = sum(t.iteration_time
                for t in simulate_period(prof, part, fills)) / H
    ssgd = ssgd_iteration_time(prof)
    asc = ascwfbp_iteration_time(prof)
    fl = flsgd_period_time(prof, H) / H
    counts = plan.meta["partition_counts"]
    print(f"{bw:12.0e} {prof.comm_compute_ratio():7.2f} "
          f"{str(counts):>22} {dream * 1e3:9.1f} {ssgd * 1e3:9.1f} "
          f"{asc * 1e3:9.1f} {fl * 1e3:9.1f} {asc / dream:6.2f} "
          f"{fl / dream:6.2f}")

print("\nS1 = speedup vs ASC-WFBP, S2 = vs FLSGD (paper Table 1 ranges: "
      "1.73-5.22x and 1.16-1.50x)")
