"""Continuous-batching serving example on a reduced config.

Submits a mixed-length request set (short + long prompts, one early-EOS
request, one sampled request) to the ServeEngine and streams tokens as
they are generated.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-moe-30b-a3b
"""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.serve import (EngineConfig, Request, SamplingParams, ServeEngine)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    model = arch.make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    requests = [
        Request(tokens=rng.randint(0, model.cfg.vocab, size=n).tolist(),
                max_new_tokens=args.gen,
                eos_id=3 if i == 1 else None,
                sampling=(SamplingParams(temperature=0.8, top_k=40, seed=7)
                          if i == 2 else SamplingParams()))
        for i, n in enumerate((24, 8, 16, 24))]

    engine = ServeEngine(
        model, params,
        EngineConfig(max_batch=args.max_batch, max_seq=24 + args.gen),
        frontend=arch.frontend)
    for req in requests:
        engine.submit(req, on_token=lambda rid, tok, i:
                      print(f"  req {rid} token[{i}] = {tok}"))
    while engine.has_work:
        for comp in engine.step():
            print(f"done: req {comp.request_id} ({comp.finish_reason}) "
                  f"-> {comp.tokens}")

    st = engine.stats
    print(f"\n{st.requests_completed} requests, "
          f"{st.generated_tokens} tokens, "
          f"{st.decode_tokens_per_s:.1f} decode tok/s, "
          f"slot utilization {st.slot_utilization:.2f}")
