"""Batched serving example: prefill + greedy decode on a reduced config.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-moe-30b-a3b
"""

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch",
                str(args.batch), "--prompt-len", "24", "--gen", "8"])
